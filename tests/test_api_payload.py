"""SchedulingPayload contract tests: lossless JSON round-trip and strict,
actionable upfront validation."""

import json

import pytest

from repro.api import (
    ClusterSpec,
    ComponentSpec,
    EdgeSpec,
    NodeEntry,
    PayloadValidationError,
    RunSettings,
    SchedulerSpec,
    SchedulingPayload,
    TopologySpec,
)
from repro.stream import topologies


def linear_spec(tid="lin", mem=512.0) -> TopologySpec:
    return TopologySpec(
        id=tid,
        components=(
            ComponentSpec(id="spout", is_spout=True, parallelism=2, memory_load_mb=mem),
            ComponentSpec(id="bolt", parallelism=2, memory_load_mb=mem),
        ),
        edges=(EdgeSpec("spout", "bolt"),),
    )


def make_payload(**over) -> SchedulingPayload:
    kw = dict(
        topology=linear_spec(),
        cluster=ClusterSpec(preset="emulab_12"),
        scheduler=SchedulerSpec("rstorm"),
        settings=RunSettings(),
    )
    kw.update(over)
    return SchedulingPayload(**kw)


# -- round-trip -----------------------------------------------------------------
@pytest.mark.parametrize(
    "scheduler",
    [
        {"name": "rstorm", "kwargs": {}},
        {"name": "round_robin", "kwargs": {"seed": 3, "slot_mode": "node_major"}},
        {"name": "rstorm_annealed", "kwargs": {"iters": 800, "seed": 1}},
    ],
)
@pytest.mark.parametrize("preset", ["emulab_12", "emulab_24"])
def test_pure_dict_payload_roundtrips_unchanged(scheduler, preset):
    """Acceptance: 3 schedulers x both emulab clusters, dict -> payload -> dict."""
    raw = {
        "topology": topologies.spec("pageload").to_dict(),
        "cluster": {"preset": preset},
        "scheduler": scheduler,
        "settings": {"allow_partial": True, "simulate": False},
    }
    raw = json.loads(json.dumps(raw))  # prove it's pure JSON
    payload = SchedulingPayload.from_dict(raw)
    assert payload.to_dict() == raw
    # And a second pass is a fixed point.
    assert SchedulingPayload.from_dict(payload.to_dict()).to_dict() == raw


def test_programmatic_payload_roundtrips_through_json():
    p = make_payload(
        cluster=ClusterSpec(
            nodes=(
                NodeEntry("n0", "r0"),
                NodeEntry("n1", "r0", cpu_capacity=200.0, num_worker_slots=2),
            )
        ),
        scheduler=SchedulerSpec("rstorm_annealed", {"iters": 42}),
        settings=RunSettings(allow_partial=False, simulate=True),
    )
    assert SchedulingPayload.from_dict(json.loads(json.dumps(p.to_dict()))) == p


def test_homogeneous_cluster_roundtrip_and_materialization():
    spec = ClusterSpec(racks=3, nodes_per_rack=2, memory_mb=4096.0)
    p = make_payload(cluster=spec)
    assert SchedulingPayload.from_dict(p.to_dict()).cluster == spec
    cl = spec.to_cluster()
    assert len(cl.nodes) == 6 and len(cl.racks) == 3
    assert next(iter(cl.nodes.values())).spec.memory_capacity_mb == 4096.0


def test_topology_spec_is_faithful_to_builder_topology():
    topo = topologies.processing()
    spec = TopologySpec.from_topology(topo)
    rebuilt = spec.to_topology()
    assert rebuilt.id == topo.id and rebuilt.acked == topo.acked
    assert rebuilt.edges == topo.edges
    assert rebuilt.groupings == topo.groupings
    assert {t.id for t in rebuilt.all_tasks()} == {t.id for t in topo.all_tasks()}
    for cid, comp in topo.components.items():
        rb = rebuilt.components[cid]
        assert rb.resource_demand.values == comp.resource_demand.values
        assert rb.cpu_cost_per_tuple == comp.cpu_cost_per_tuple
        assert rb.max_rate_per_task == comp.max_rate_per_task


# -- validation errors ------------------------------------------------------------
def errors_of(fn) -> str:
    with pytest.raises(PayloadValidationError) as ei:
        fn()
    return "\n".join(ei.value.errors)


def test_unknown_scheduler_is_actionable():
    msg = errors_of(lambda: make_payload(scheduler=SchedulerSpec("rstormx")).validate())
    assert "unknown scheduler 'rstormx'" in msg and "rstorm_annealed" in msg


def test_bad_scheduler_kwargs():
    msg = errors_of(
        lambda: make_payload(
            scheduler=SchedulerSpec("rstorm_annealed", {"iters": "many", "turbo": 1})
        ).validate()
    )
    assert "scheduler.kwargs.iters: expected int" in msg
    assert "scheduler.kwargs.turbo: unknown kwarg" in msg
    msg = errors_of(
        lambda: make_payload(
            scheduler=SchedulerSpec("round_robin", {"slot_mode": "diagonal"})
        ).validate()
    )
    assert "must be one of" in msg and "port_major" in msg


def test_cyclic_topology_rejected():
    spec = TopologySpec(
        id="cyc",
        components=(
            ComponentSpec(id="s", is_spout=True),
            ComponentSpec(id="a"),
            ComponentSpec(id="b"),
        ),
        edges=(EdgeSpec("s", "a"), EdgeSpec("a", "b"), EdgeSpec("b", "a")),
    )
    msg = errors_of(lambda: make_payload(topology=spec).validate())
    assert "cycle detected" in msg and "'a'" in msg and "'b'" in msg


def test_disconnected_topology_rejected():
    spec = TopologySpec(
        id="disc",
        components=(
            ComponentSpec(id="s", is_spout=True),
            ComponentSpec(id="island"),
        ),
    )
    msg = errors_of(lambda: make_payload(topology=spec).validate())
    assert "unreachable from any spout" in msg and "island" in msg


def test_unknown_edge_endpoint_negative_load_no_spout():
    spec = TopologySpec(
        id="bad",
        components=(
            ComponentSpec(id="a", memory_load_mb=-5.0),
            ComponentSpec(id="a", parallelism=0),
        ),
        edges=(EdgeSpec("a", "zzz"),),
    )
    msg = errors_of(lambda: make_payload(topology=spec).validate())
    assert "memory_load_mb: must be a number >= 0" in msg
    assert "duplicate component id 'a'" in msg
    assert "parallelism: must be an int >= 1" in msg
    assert "unknown component 'zzz'" in msg
    assert "no spout" in msg


def test_cluster_spec_modes_are_exclusive_and_checked():
    msg = errors_of(lambda: make_payload(cluster=ClusterSpec()).validate())
    assert "exactly one of" in msg
    msg = errors_of(
        lambda: make_payload(
            cluster=ClusterSpec(preset="emulab_12", racks=2, nodes_per_rack=2)
        ).validate()
    )
    assert "mutually exclusive" in msg
    msg = errors_of(lambda: make_payload(cluster=ClusterSpec(preset="emulab_3")).validate())
    assert "unknown preset 'emulab_3'" in msg
    msg = errors_of(
        lambda: make_payload(
            cluster=ClusterSpec(nodes=(NodeEntry("n0", "r0"), NodeEntry("n0", "r1")))
        ).validate()
    )
    assert "duplicate node id 'n0'" in msg


def test_from_dict_rejects_unknown_keys_and_missing_sections():
    msg = errors_of(lambda: SchedulingPayload.from_dict({"topology": {}}))
    assert "payload.cluster: required key missing" in msg
    assert "payload.scheduler: required key missing" in msg
    p = make_payload()
    raw = p.to_dict()
    raw["topology"]["componets"] = []  # typo
    msg = errors_of(lambda: SchedulingPayload.from_dict(raw))
    assert "unknown key(s) ['componets']" in msg


def test_all_errors_reported_at_once():
    raw = {
        "topology": {
            "id": "t",
            "components": [{"id": "a", "is_spout": True}, {"id": "b"}],
            "edges": [{"src": "a", "dst": "zzz"}],
        },
        "cluster": {"preset": "emulab_99"},
        "scheduler": {"name": "rstormx"},
    }
    with pytest.raises(PayloadValidationError) as ei:
        SchedulingPayload.from_dict(raw)
    joined = "\n".join(ei.value.errors)
    assert "zzz" in joined and "emulab_99" in joined and "rstormx" in joined
    assert len(ei.value.errors) >= 3
