"""Serving example: batched request decoding through the ServingEngine
(continuous-batching-lite) on any assigned architecture's smoke config.

    PYTHONPATH=src python examples/serve_lm.py --arch smollm-360m --requests 6
"""

import argparse
import time

import jax
import numpy as np

from repro.data import ByteTokenizer
from repro.models import build
from repro.serve import Request, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new-tokens", type=int, default=12)
    args = ap.parse_args()

    model = build(args.arch, smoke=True)
    params = model.init_params(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params, batch_slots=args.slots, max_seq=64)
    tok = ByteTokenizer()
    prompts = [f"request {i}: the quick brown" for i in range(args.requests)]
    reqs = [
        Request(
            rid=i,
            prompt=tok.encode(p) % model.cfg.vocab,
            max_new_tokens=args.max_new_tokens,
        )
        for i, p in enumerate(prompts)
    ]
    t0 = time.time()
    done = engine.run(reqs, max_steps=2048)
    dt = time.time() - t0
    n_tokens = sum(len(r.output) for r in done)
    for r in done:
        print(f"req{r.rid}: done={r.done} new_tokens={len(r.output)} ids={r.output[:8]}...")
    print(
        f"\n{args.requests} requests x {args.max_new_tokens} tokens on "
        f"{args.slots} slots: {n_tokens} tokens in {dt:.1f}s "
        f"({n_tokens / dt:.1f} tok/s, untrained weights)"
    )


if __name__ == "__main__":
    main()
