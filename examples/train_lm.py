"""End-to-end training driver: data pipeline → model → AdamW → async
checkpoints, with R-Storm-planned sharding when >1 device is available.

    PYTHONPATH=src python examples/train_lm.py --preset tiny --steps 200
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300   # deliverable-scale
    PYTHONPATH=src python examples/train_lm.py --arch qwen3-0.6b --smoke   # any assigned arch

The 100m preset is the assignment's "train a ~100M model for a few hundred
steps" driver; on this CPU-only container use --preset tiny for a quick run
(same code path, smaller dims).
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import ModelConfig
from repro.data import LMDataset, Prefetcher
from repro.models import build, build_from_config
from repro.train import (
    AdamWConfig,
    AsyncCheckpointer,
    TrainOptions,
    init_train_state,
    latest_step,
    make_train_step,
    restore_checkpoint,
)

PRESETS = {
    "tiny": ModelConfig(
        arch="tiny-lm", family="dense", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, d_ff=512, vocab=512, pattern=("attn",), remat="none",
    ),
    # ~100M params (llama-ish): 12L x 768 with GQA and a 32k byte-vocab.
    "100m": ModelConfig(
        arch="lm-100m", family="dense", n_layers=12, d_model=768, n_heads=12,
        n_kv_heads=4, d_ff=2048, vocab=32768, pattern=("attn",),
    ),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
    ap.add_argument("--arch", default=None, help="assigned arch id instead of a preset")
    ap.add_argument("--smoke", action="store_true", help="reduced config for --arch")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    if args.arch:
        model = build(args.arch, smoke=args.smoke)
    else:
        model = build_from_config(PRESETS[args.preset])
    cfg = model.cfg
    n_params = cfg.param_count()
    print(f"arch={cfg.arch} params≈{n_params/1e6:.1f}M vocab={cfg.vocab}")

    opts = TrainOptions(
        opt=AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps)
    )
    state = init_train_state(model, jax.random.PRNGKey(0), opts)
    start = 0
    if args.resume and latest_step(args.ckpt_dir) is not None:
        like = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state
        )
        state, start = restore_checkpoint(args.ckpt_dir, like)
        print(f"resumed from step {start}")

    step_fn = jax.jit(make_train_step(model, opts), donate_argnums=(0,))
    ds = Prefetcher(
        iter(LMDataset(seq_len=args.seq_len, batch_size=args.batch, vocab_size=cfg.vocab))
    )
    ckpt = AsyncCheckpointer(args.ckpt_dir, keep=2)
    t0 = time.time()
    for i in range(start, args.steps):
        batch = next(ds)
        state, metrics = step_fn(state, {k: jnp.asarray(v) for k, v in batch.items()})
        if (i + 1) % 10 == 0:
            dt = time.time() - t0
            print(
                f"step {i + 1:5d} loss={float(metrics['loss']):.4f} "
                f"lr={float(metrics['lr']):.2e} gnorm={float(metrics['grad_norm']):.2f} "
                f"({dt / max(i + 1 - start, 1):.2f}s/step)"
            )
        if (i + 1) % args.ckpt_every == 0:
            ckpt.save(i + 1, state)
    ckpt.close()
    print(f"done: {args.steps} steps, checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
