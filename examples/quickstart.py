"""Quickstart: schedule the paper's topologies with R-Storm vs default Storm
and simulate throughput (paper Fig 8/12 in one minute).

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import (
    RoundRobinScheduler,
    RStormScheduler,
    emulab_cluster,
)
from repro.stream import Simulator, topologies


def main() -> None:
    cluster = emulab_cluster()
    sim = Simulator(cluster)
    print(f"cluster: {cluster}")
    print(f"{'topology':14s} {'default':>12s} {'rstorm':>12s} {'gain':>8s}  binding/machines")
    for maker in (
        lambda: topologies.linear(network_bound=True),
        lambda: topologies.diamond(network_bound=True),
        lambda: topologies.star(network_bound=True),
        topologies.pageload,
        topologies.processing,
    ):
        topo = maker()
        cluster.reset()
        rr = RoundRobinScheduler(seed=1).schedule(topo, cluster, commit=False)
        cluster.reset()
        rs = RStormScheduler().schedule(topo, cluster, commit=False)
        cluster.reset()
        res_rr = sim.run(topo, rr)
        res_rs = sim.run(topo, rs)
        gain = (res_rs.sink_throughput / max(res_rr.sink_throughput, 1e-9) - 1) * 100
        print(
            f"{topo.id:14s} {res_rr.sink_throughput:10.0f}/s {res_rs.sink_throughput:10.0f}/s "
            f"{gain:+7.1f}%  {res_rs.binding}, {res_rs.machines_used} vs "
            f"{res_rr.machines_used} machines"
        )
    print(
        "\nR-Storm packs communicating tasks onto few machines under the hard"
        "\nmemory constraint — the default scheduler scatters them (paper §6)."
    )


if __name__ == "__main__":
    main()
