"""Quickstart: the payload-driven control plane.

Schedule the paper's topologies with R-Storm vs default Storm and simulate
throughput (paper Fig 8/12 in one minute) — every run is one declarative
``SchedulingPayload`` (dict -> from_dict -> Nimbus.plan), so schedulers,
clusters and workloads are data, not hand-wired Python.

    PYTHONPATH=src python examples/quickstart.py
"""

import json

from repro.api import Nimbus, SchedulingPayload
from repro.stream import topologies


def payload_dict(topo_name: str, scheduler: str, kwargs=None, **topo_kwargs) -> dict:
    """A pure-dict payload: exactly what a JSON/YAML scenario file holds."""
    return {
        "topology": topologies.spec(topo_name, **topo_kwargs).to_dict(),
        "cluster": {"preset": "emulab_12"},
        "scheduler": {"name": scheduler, "kwargs": dict(kwargs or {})},
        "settings": {"allow_partial": True, "simulate": True},
    }


def main() -> None:
    nimbus = Nimbus()
    print(f"{'topology':14s} {'default':>12s} {'rstorm':>12s} {'gain':>8s}  binding/machines")
    for name, topo_kwargs in (
        ("linear", {"network_bound": True}),
        ("diamond", {"network_bound": True}),
        ("star", {"network_bound": True}),
        ("pageload", {}),
        ("processing", {}),
    ):
        results = {}
        for sched, kwargs in (("round_robin", {"seed": 1}), ("rstorm", {})):
            raw = payload_dict(name, sched, kwargs, **topo_kwargs)
            # Through JSON and back: the payload is lossless, validated data.
            payload = SchedulingPayload.from_dict(json.loads(json.dumps(raw)))
            results[sched] = nimbus.plan(payload)  # dry-run: commits nothing
        rr, rs = results["round_robin"].sim, results["rstorm"].sim
        gain = (rs.sink_throughput / max(rr.sink_throughput, 1e-9) - 1) * 100
        print(
            f"{rs.topology_id:14s} {rr.sink_throughput:10.0f}/s {rs.sink_throughput:10.0f}/s "
            f"{gain:+7.1f}%  {rs.binding}, {rs.machines_used} vs "
            f"{rr.machines_used} machines"
        )
    print(
        "\nR-Storm packs communicating tasks onto few machines under the hard"
        "\nmemory constraint — the default scheduler scatters them (paper §6)."
    )


if __name__ == "__main__":
    main()
