"""Fault-tolerance walkthrough (DESIGN.md §5) as one declarative scenario:
the whole cluster lifecycle — submit, node failure, rebalance, straggler
migration, mass failure, elastic scale-up, kill — is a ``ScenarioSpec``
timeline (pure data, JSON-round-trippable) replayed by ``ScenarioRunner``
through the single ``Nimbus.apply(event)`` dispatcher.

    PYTHONPATH=src python examples/elastic_failover.py
"""

from repro.api import (
    ClusterSpec,
    KillEvent,
    Nimbus,
    NodeEntry,
    NodeFailEvent,
    NodeJoinEvent,
    RebalanceEvent,
    ScenarioRunner,
    ScenarioSpec,
    SchedulerSpec,
    SchedulingPayload,
    StragglerReportEvent,
    SubmitEvent,
)
from repro.stream import topologies

CLUSTER = ClusterSpec(preset="emulab_12")


def build_scenario() -> ScenarioSpec:
    topo_spec = topologies.spec("pageload")
    # Pick the failure victims from a dry-run plan (deterministic for rstorm),
    # then freeze them into the timeline — the scenario itself is static data.
    plan = Nimbus().plan(
        SchedulingPayload(
            topology=topo_spec, cluster=CLUSTER, scheduler=SchedulerSpec("rstorm")
        )
    )
    victim = sorted(set(plan.placements.values()))[0]
    # 8 of the 12 nodes die in total: 4 × 2 GB survivors cannot hold
    # PageLoad's ~8.4 GB, so tasks stay unplaced until a fresh rack joins.
    doomed = [nid for nid in sorted(CLUSTER.to_cluster().nodes) if nid != victim][:7]
    service_times = {tid: 0.002 for tid in plan.placements}
    straggler = sorted(plan.placements)[0]
    service_times[straggler] = 1.0  # 500x the component median

    return ScenarioSpec(
        name="elastic_failover",
        cluster=CLUSTER,
        timeline=(
            SubmitEvent(topology=topo_spec, scheduler=SchedulerSpec("rstorm")),
            NodeFailEvent(node_id=victim),
            RebalanceEvent(),
            StragglerReportEvent(service_times=service_times),
            *[NodeFailEvent(node_id=nid) for nid in doomed],
            RebalanceEvent(),
            NodeJoinEvent(
                nodes=tuple(NodeEntry(f"fresh{i}", "rack_fresh") for i in range(6))
            ),
            KillEvent(topology_id="pageload"),
        ),
    )


def main() -> None:
    spec = build_scenario()

    # The scenario is data: it survives a JSON round-trip losslessly and the
    # replay is deterministic — same timeline, same trace, bit for bit.
    replayed = ScenarioSpec.from_json(spec.to_json())
    assert replayed.to_dict() == spec.to_dict()
    trace = ScenarioRunner(spec).run()
    assert ScenarioRunner(replayed).run().to_dict() == trace.to_dict()

    print(f"replaying {spec.name!r}: {len(spec.timeline)} events\n")
    for entry in trace.entries:
        kind = entry.event["kind"]
        tp = entry.topologies.get("pageload", {}).get("sink_throughput")
        tp_s = f"{tp:8.1f}/s" if tp is not None else "   (none)"
        moved = sum(len(v) for v in entry.outcome.get("moved", {}).values())
        unplaced = sum(len(v) for v in entry.unplaced.values())
        detail = []
        if kind == "node_fail":
            detail.append(
                f"{entry.event['node_id']} down, "
                f"{len(entry.outcome['orphaned'])} orphans"
            )
        if kind == "node_join":
            detail.append(f"+{len(entry.event['nodes'])} nodes")
        if kind == "straggler_report":
            detail.append(f"migrated {entry.outcome['moves']}")
        if moved:
            detail.append(f"moved={moved}")
        print(
            f"  [{entry.step:2d}] {kind:17s} throughput={tp_s} "
            f"machines={entry.machines_used:2d} alive={entry.alive_nodes:2d} "
            f"unplaced={unplaced:2d}  {'; '.join(detail)}"
        )

    # After the fresh rack joined, everything was re-placed...
    scale_up = next(e for e in trace.entries if e.event["kind"] == "node_join")
    assert scale_up.unplaced == {}, "scale-up must land every task"
    # ...and the kill returned all resources.
    assert trace.final().topologies == {}
    print("\nevery task re-placed after scale-up; kill returned the cluster.")
    print("the trace is a pure function of the scenario JSON.")


if __name__ == "__main__":
    main()
