"""Fault-tolerance walkthrough (DESIGN.md §5), on the Nimbus facade:

1. submit the Yahoo PageLoad topology as a declarative payload;
2. kill a worker node — ``Nimbus.rebalance()`` re-places only the orphans;
3. detect and migrate a straggler via the StatisticServer feed;
4. scale the cluster up elastically and watch unassigned tasks land;
5. kill the topology — its resources return to the cluster.

    PYTHONPATH=src python examples/elastic_failover.py
"""

from repro.api import (
    ClusterSpec,
    Nimbus,
    RunSettings,
    SchedulerSpec,
    SchedulingPayload,
    TopologySpec,
)
from repro.core import NodeSpec, Rescheduler, StragglerMitigator
from repro.stream import Simulator, topologies


def show(sim, topo, assignment, label):
    res = sim.run(topo, assignment)
    print(
        f"  [{label}] throughput={res.sink_throughput:8.1f}/s "
        f"machines={res.machines_used} binding={res.binding} "
        f"unassigned={len(assignment.unassigned)}"
    )
    return res


def main() -> None:
    payload = SchedulingPayload(
        topology=TopologySpec.from_topology(topologies.pageload()),
        cluster=ClusterSpec(preset="emulab_12"),
        scheduler=SchedulerSpec("rstorm"),
        settings=RunSettings(allow_partial=True),
    )
    nimbus = Nimbus()
    print(f"1) submitting {payload.topology.id!r} via Nimbus")
    plan = nimbus.submit(payload)
    topo, assignment = plan.topology, plan.assignment
    sim = Simulator(nimbus.cluster)
    show(sim, topo, assignment, "initial")

    victim = sorted(set(assignment.placements.values()))[0]
    print(f"\n2) node failure: {victim}")
    nimbus.cluster.fail_node(victim)
    orphans = nimbus.state.orphaned_tasks()  # (topology_id, task_id) pairs
    print(f"   orphaned: {[tid for _, tid in orphans]}")
    moved = nimbus.rebalance()
    print(f"   migrated tasks: {moved.get(topo.id, [])}")
    show(sim, topo, assignment, "after failover")

    print("\n3) straggler mitigation")
    times = {t.id: 0.002 for t in topo.all_tasks()}
    straggler = next(iter(assignment.placements))
    times[straggler] = 1.0
    mit = StragglerMitigator(nimbus.state)
    found = mit.find_stragglers(times)
    moves = mit.migrate(found)
    print(f"   detected {found} -> moved to {list(moves.values())}")

    print("\n4) elastic scale-up: fail half the cluster, then add a fresh rack")
    resch = Rescheduler(nimbus.state)
    for nid in list(assignment.nodes_used())[:3]:
        resch.handle_node_failure(nid)
    print(f"   after failures: unassigned={len(assignment.unassigned)}")
    resch.handle_scale_up(
        [NodeSpec(f"fresh{i}", "rack_fresh", 100.0, 2048.0) for i in range(6)]
    )
    show(sim, topo, assignment, "after scale-up")
    assert assignment.is_complete(topo)

    print("\n5) kill: resources return to the cluster")
    nimbus.kill(topo.id)
    free = nimbus.cluster.total_available()["memory_mb"]
    print(f"   topologies={nimbus.topologies}, free memory={free:.0f} MB")
    print("\nall tasks placed; the plan is a pure function of (topology, cluster).")


if __name__ == "__main__":
    main()
