"""Fault-tolerance walkthrough (DESIGN.md §5):

1. schedule the Yahoo PageLoad topology with R-Storm;
2. kill a worker node — the rescheduler re-places only the orphaned tasks;
3. detect and migrate a straggler via the StatisticServer feed;
4. scale the cluster up elastically and watch unassigned tasks land.

    PYTHONPATH=src python examples/elastic_failover.py
"""

from repro.core import (
    GlobalState,
    NodeSpec,
    Rescheduler,
    RStormScheduler,
    StragglerMitigator,
    emulab_cluster,
)
from repro.stream import Simulator, topologies


def show(sim, topo, assignment, label):
    res = sim.run(topo, assignment)
    print(
        f"  [{label}] throughput={res.sink_throughput:8.1f}/s "
        f"machines={res.machines_used} binding={res.binding} "
        f"unassigned={len(assignment.unassigned)}"
    )
    return res


def main() -> None:
    cluster = emulab_cluster()
    gs = GlobalState(cluster)
    topo = topologies.pageload()
    print(f"1) scheduling {topo.id} on {cluster}")
    assignment = gs.submit(topo, RStormScheduler())
    sim = Simulator(cluster)
    show(sim, topo, assignment, "initial")

    victim = assignment.nodes_used()[0]
    print(f"\n2) node failure: {victim}")
    resch = Rescheduler(gs)
    moved = resch.handle_node_failure(victim)
    print(f"   migrated tasks: {moved.get(topo.id, [])}")
    show(sim, topo, assignment, "after failover")

    print("\n3) straggler mitigation")
    times = {t.id: 0.002 for t in topo.all_tasks()}
    straggler = next(iter(assignment.placements))
    times[straggler] = 1.0
    mit = StragglerMitigator(gs)
    found = mit.find_stragglers(times)
    moves = mit.migrate(found)
    print(f"   detected {found} -> moved to {list(moves.values())}")

    print("\n4) elastic scale-up: fail half the cluster, then add a fresh rack")
    for nid in list(assignment.nodes_used())[:3]:
        resch.handle_node_failure(nid)
    print(f"   after failures: unassigned={len(assignment.unassigned)}")
    resch.handle_scale_up(
        [NodeSpec(f"fresh{i}", "rack_fresh", 100.0, 2048.0) for i in range(6)]
    )
    show(sim, topo, assignment, "after scale-up")
    assert assignment.is_complete(topo)
    print("\nall tasks placed; the plan is a pure function of (topology, cluster).")


if __name__ == "__main__":
    main()
